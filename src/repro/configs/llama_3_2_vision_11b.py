"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Vision frontend is a STUB per assignment: input_specs provides precomputed
patch embeddings (B, 1600, 1280) fed through a linear projector into the
gated cross-attention layers (8 cross layers interleaved with the 32
self-attention layers of the Llama-3.1-8B text trunk -> 40 total).
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    layer_pattern=("attn", "attn", "attn", "attn", "cross"),
    rope_theta=500000.0,
    mlp="swiglu",
    norm="rmsnorm",
    norm_eps=1e-5,
    tie_embeddings=False,
    vision_tokens=1600,
    vision_dim=1280,
    sub_quadratic=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="llama-3.2-vision-11b-smoke", num_layers=5, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    vision_tokens=8, vision_dim=32, dtype="float32", param_dtype="float32",
)
