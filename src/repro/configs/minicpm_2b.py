"""minicpm-2b [dense] — 40L d_model=2304 36H (MHA kv=36) d_ff=5760
vocab=122753; Llama-like arch trained with the WSD schedule.
[arXiv:2404.06395; hf]

MiniCPM specifics: embedding scale 12, depth-scaled residuals
(scale_depth 1.4 / sqrt(L)), logits scaled by dim_model_base/d_model =
256/2304, tied embeddings. The WSD (warmup-stable-decay) schedule is the
training-side counterpart — see repro.optim.optimizer.wsd_schedule.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    source="[arXiv:2404.06395; hf]",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    layer_pattern=("attn",),
    mlp="swiglu",
    norm="rmsnorm",
    norm_eps=1e-5,
    emb_scale=12.0,
    residual_scale=1.4 / 40.0 ** 0.5,
    logit_scale=256.0 / 2304.0,
    tie_embeddings=True,
    sub_quadratic=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="minicpm-2b-smoke", num_layers=3, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, emb_scale=12.0,
    residual_scale=1.4 / 3.0 ** 0.5, logit_scale=0.5, dtype="float32",
    param_dtype="float32",
)
