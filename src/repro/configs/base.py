"""Architecture config system: dataclass, registry, CLI overrides.

Every assigned architecture is a frozen :class:`ArchConfig` in its own
module under ``repro.configs``; ``get_config(name)`` returns the exact
assigned configuration, ``get_config(name, smoke=True)`` a reduced
same-family variant for CPU smoke tests. ``apply_overrides`` implements
``--set field=value`` launcher overrides.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

__all__ = ["ArchConfig", "register", "get_config", "list_archs", "apply_overrides"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                 # provenance note "[arXiv:... ; tier]"

    # trunk dimensions
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: Optional[int] = None   # default: d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0

    # block pattern: kinds repeated (truncated) to num_layers.
    # kinds: attn | local | cross | rglru | slstm | mlstm
    layer_pattern: Tuple[str, ...] = ("attn",)

    # attention details
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"      # rope | sinusoidal | none
    local_window: Optional[int] = None
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qk_norm: bool = False
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)

    # mlp / norms
    mlp: str = "swiglu"              # swiglu | geglu | gelu (plain, non-gated)
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-6
    post_norms: bool = False         # gemma2-style pre+post block norms

    # embeddings / head
    emb_scale: Optional[float] = None      # e.g. sqrt(d) (gemma), 12 (minicpm)
    logit_scale: Optional[float] = None    # e.g. minicpm 256/d_model
    tie_embeddings: bool = True
    residual_scale: Optional[float] = None # minicpm scale_depth/sqrt(L)

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_loss_coef: float = 0.01

    # recurrent (RG-LRU / xLSTM)
    rnn_width: Optional[int] = None  # RG-LRU lru_width
    conv_width: int = 4              # temporal conv kernel

    # modality stubs
    vision_tokens: int = 0           # [vlm] number of precomputed patch embeds
    vision_dim: int = 0              # [vlm] patch embedding dim (pre-projector)
    num_codebooks: int = 0           # [audio] EnCodec codebooks

    # numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    sub_quadratic: bool = False      # eligible for long_500k
    kv_quant: bool = False           # int8 KV cache (serving memory lever)

    def __post_init__(self):
        if self.head_dim is None and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        reps = -(-self.num_layers // len(self.layer_pattern))
        return (self.layer_pattern * reps)[: self.num_layers]

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim or 0
        total = v * d * (1 if self.tie_embeddings else 2)
        if self.num_codebooks:
            total = self.num_codebooks * v * d * 2
        for kind in self.layer_kinds:
            if kind in ("attn", "local", "cross"):
                total += d * hd * (self.num_heads + 2 * self.num_kv_heads)
                total += self.num_heads * hd * d
            if kind == "rglru":
                r = self.rnn_width or d
                total += 2 * d * r + r * d + self.conv_width * r + 3 * r
            if kind == "mlstm":
                total += 2 * d * 2 * d + 3 * (2 * d) * (2 * d) // 4 + 2 * d * d
            if kind == "slstm":
                total += 4 * d * d + 4 * d * d // 4 + int(2 * 4 / 3 * d * d)
            if kind in ("attn", "local", "cross", "rglru"):
                if self.moe_experts:
                    total += self.moe_experts * 3 * d * f + d * self.moe_experts
                elif f:
                    gated = self.mlp in ("swiglu", "geglu")
                    total += (3 if gated else 2) * d * f
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.moe_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense = self.param_count() - self.num_layers * self.moe_experts * 3 * d * f
        return dense + self.num_layers * self.moe_top_k * 3 * d * f


_REGISTRY: dict[str, str] = {}   # name -> module path


def register(name: str, module: str) -> None:
    _REGISTRY[name] = module


# The 10 assigned architectures.
for _n, _m in {
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    # the paper's own workload has no transformer; see repro.launch.probe
}.items():
    register(_n, _m)


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    mod = importlib.import_module(_REGISTRY[name])
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def apply_overrides(cfg: ArchConfig, overrides: list[str]) -> ArchConfig:
    """--set field=value (int/float/str/bool auto-coerced)."""
    updates = {}
    for item in overrides:
        field, _, raw = item.partition("=")
        f = {f.name: f for f in dataclasses.fields(ArchConfig)}[field]
        if raw in ("true", "True", "false", "False"):
            val = raw.lower() == "true"
        else:
            try:
                val = int(raw)
            except ValueError:
                try:
                    val = float(raw)
                except ValueError:
                    val = raw
        updates[field] = val
    return dataclasses.replace(cfg, **updates)
