"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention, temporal pattern (R, R, A).
[arXiv:2402.19427; hf]

Griffin recipe: blocks of two RG-LRU recurrent mixers followed by one
local (window 2048) MQA attention layer; GeGLU MLPs; Gemma-style
sqrt(d_model) embedding scaling; tied embeddings. 26 = (R,R,A)×8 + (R,R).
Sub-quadratic: O(1) recurrent state + bounded local window -> long_500k.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="[arXiv:2402.19427; hf]",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    rnn_width=2560,
    conv_width=4,
    mlp="geglu",
    norm="rmsnorm",
    emb_scale=2560.0 ** 0.5,
    query_scale=256.0 ** -0.5,
    tie_embeddings=True,
    sub_quadratic=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="recurrentgemma-2b-smoke", num_layers=5, d_model=64,
    num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
    rnn_width=64, local_window=16, emb_scale=8.0, query_scale=16.0 ** -0.5,
    dtype="float32", param_dtype="float32",
)
