"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768/expert
vocab=151936; 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

Qwen3-MoE specifics: every MLP is an MoE (128 experts, top-8, renormalised
gates, no shared expert), QK-norm, head_dim 128, RoPE theta 1e6, untied
embeddings. ~30B total / ~3B active parameters.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    layer_pattern=("attn",),
    rope_theta=1_000_000.0,
    qk_norm=True,
    mlp="swiglu",
    norm="rmsnorm",
    moe_experts=128,
    moe_top_k=8,
    moe_capacity_factor=1.25,
    tie_embeddings=False,
    sub_quadratic=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="qwen3-moe-30b-a3b-smoke", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=512,
    moe_experts=8, moe_top_k=2, dtype="float32", param_dtype="float32",
)
