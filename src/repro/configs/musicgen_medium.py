"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Audio frontend is a STUB per assignment: the model consumes 4 parallel
EnCodec codebook token streams (B, 4, S); codebook embeddings are summed
(MusicGen's delay-pattern sum) and each codebook has its own LM head.
LayerNorm + plain-GELU MLP + sinusoidal positions per the paper.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    source="[arXiv:2306.05284; hf]",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    layer_pattern=("attn",),
    pos_embedding="sinusoidal",
    mlp="gelu",
    norm="layernorm",
    norm_eps=1e-5,
    tie_embeddings=False,
    num_codebooks=4,
    sub_quadratic=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="musicgen-medium-smoke", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128,
    num_codebooks=2, dtype="float32", param_dtype="float32",
)
