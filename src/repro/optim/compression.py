"""Int8 error-feedback gradient compression (distributed-optimization trick).

At 1000+ node scale the data-parallel gradient all-reduce dominates the
step's collective bytes. Quantising gradients to int8 with a per-tensor
scale cuts those bytes 4x (vs f32 grads); the quantisation error is fed
back into the next step's gradient (error feedback, à la 1-bit SGD /
PowerSGD practice) so convergence is preserved.

The transform is applied *before* the optimizer consumes the (already
psum-med) gradients in this single-controller setting; on a real fleet the
quantised representation is what crosses the DCN (the all-reduce is then
performed in int8 blocks with f32 scales). The numerics — quantise,
dequantise, error-feedback — are identical, which is what the tests and
convergence checks validate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_decompress"]


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, err):
    """Per-leaf int8 round-trip with error feedback.

    grads, err: matching f32 pytrees. Returns (decompressed grads, new err).
    """
    def one(g, e):
        g_fb = g + e
        q, s = quantize_int8(g_fb)
        deq = dequantize_int8(q, s)
        return deq, g_fb - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deqs = treedef.unflatten([o[0] for o in out])
    errs = treedef.unflatten([o[1] for o in out])
    return deqs, errs
