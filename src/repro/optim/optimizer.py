"""AdamW optimizer with bf16-param / f32-master mixed precision, LR
schedules (cosine + MiniCPM's WSD), global-norm clipping, and optional
int8 error-feedback gradient compression.

No optax dependency (offline container): a small, explicit implementation
whose state pytree mirrors the param tree — which is exactly what lets the
ZeRO-1 sharding rules (repro.launch.sharding.opt_state_spec) shard the
master/moment tensors over the "data" axis independently of the bf16
params' TP layout.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim import compression

__all__ = ["AdamWConfig", "init_opt_state", "apply_updates", "wsd_schedule",
           "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"          # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1           # WSD: fraction of steps in decay phase
    compress_grads: bool = False      # int8 error-feedback DP compression


def cosine_schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr_peak * warm * (0.5 * (1.0 + jnp.cos(jnp.pi * t)))


def wsd_schedule(step, cfg: AdamWConfig):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395 §4): linear warmup,
    long stable plateau at peak LR, short exponential-ish decay tail."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    decay_steps = int(cfg.total_steps * cfg.decay_frac)
    decay_start = cfg.total_steps - decay_steps
    in_decay = (step - decay_start) / jnp.maximum(decay_steps, 1)
    decay = jnp.where(step >= decay_start,
                      0.5 ** jnp.clip(in_decay, 0.0, 1.0) * 2.0
                      * 0.5 ** (3.0 * jnp.clip(in_decay, 0.0, 1.0)), 1.0)
    return cfg.lr_peak * warm * jnp.minimum(decay, 1.0)


def _lr(step, cfg: AdamWConfig):
    if cfg.schedule == "wsd":
        return wsd_schedule(step, cfg)
    if cfg.schedule == "cosine":
        return cosine_schedule(step, cfg)
    return jnp.asarray(cfg.lr_peak)


class OptState(NamedTuple):
    step: jax.Array
    master: dict       # f32 master params
    mu: dict           # first moment (f32)
    nu: dict           # second moment (f32)
    err: Optional[dict]  # compression error feedback (f32) or None


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    # copy=True: the f32 master must never alias the (donatable) params
    f32 = lambda t: jnp.array(t, dtype=jnp.float32, copy=True)
    zeros = lambda t: jnp.zeros(t.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        err=jax.tree.map(zeros, params) if cfg.compress_grads else None,
    )


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params (param_dtype), new_state, stats)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    err = state.err
    if cfg.compress_grads:
        grads, err = compression.compress_decompress(grads, state.err)

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    lr = _lr(step, cfg)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(m, g):
        return cfg.b1 * m + (1 - cfg.b1) * g

    def upd2(v, g):
        return cfg.b2 * v + (1 - cfg.b2) * g * g

    mu = jax.tree.map(upd, state.mu, grads)
    nu = jax.tree.map(upd2, state.nu, grads)

    def new_master(w, m, v):
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        return w - lr * (update + cfg.weight_decay * w)

    master = jax.tree.map(new_master, state.master, mu, nu)
    new_params = jax.tree.map(lambda w, old: w.astype(old.dtype), master, params)
    new_state = OptState(step=step, master=master, mu=mu, nu=nu, err=err)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
