"""Pallas TPU kernel: fused permutation-batch hat application.

Computes, in one pass over H,

    Yhat = H @ Y        and        E = Y - H @ Y

for a permutation batch Y of shape (N, B) (Algorithm 1's inner product
``ŷ ← H yσ`` for B permutations at once). Fusing the subtraction saves one
full (N, B) HBM round-trip per permutation chunk — on TPU this matmul is
HBM-bandwidth-bound for the small B of a chunk, so the fusion removes a
third of the memory traffic (write ŷ, read ŷ, write ê → write ê only).

Grid: (N/bn, B/bb, N/bk), contraction over the last axis with an f32 VMEM
accumulator; the Y_Te diagonal block needed for the subtraction is the
second input with a (i, b)-indexed BlockSpec.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_B = 128


def _hat_apply_kernel(h_ref, y_k_ref, y_i_ref, err_ref, acc_ref, *, n_chunks: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(h_ref[...], y_k_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_chunks - 1)
    def _store():
        err_ref[...] = (y_i_ref[...].astype(acc_ref.dtype)
                        - acc_ref[...]).astype(err_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_b", "interpret"))
def hat_apply_pallas(h: jax.Array, y: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
                     block_b: int = DEFAULT_BLOCK_B, interpret: bool = False):
    """E = Y − H Y. h: (N, N), y: (N, B); N % block_n == 0, B % block_b == 0."""
    n, b = y.shape
    assert h.shape == (n, n)
    assert n % block_n == 0 and b % block_b == 0
    grid = (n // block_n, b // block_b, n // block_n)
    acc_dtype = jnp.float32 if h.dtype in (jnp.bfloat16, jnp.float16, jnp.float32) else h.dtype

    return pl.pallas_call(
        functools.partial(_hat_apply_kernel, n_chunks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_n), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_b), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_n, block_b), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_b), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, b), h.dtype),
        scratch_shapes=[pltpu.VMEM((block_n, block_b), acc_dtype)],
        interpret=interpret,
    )(h, y, y)
