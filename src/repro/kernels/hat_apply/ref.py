"""Pure-jnp oracle for hat_apply: E = Y − H Y."""

import jax


def hat_apply_ref(h: jax.Array, y: jax.Array) -> jax.Array:
    return y - h @ y
