"""Jit'd public wrapper for hat_apply (padding + dispatch)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.hat_apply.hat_apply import hat_apply_pallas

__all__ = ["hat_errors"]


@functools.partial(jax.jit, static_argnames=("block_n", "block_b", "interpret"))
def hat_errors(h: jax.Array, y: jax.Array, *, block_n: Optional[int] = None,
               block_b: Optional[int] = None, interpret: Optional[bool] = None):
    """ê = y − H y for a label batch y (N,) or (N, B) — Algorithm 1 inner step.

    Zero-padding N is safe: padded rows/cols of H are zero so padded entries
    of E are y_pad − 0 = 0 and are sliced away.
    """
    if interpret is None:
        interpret = default_interpret()
    squeeze = y.ndim == 1
    yb = y[:, None] if squeeze else y
    n, b = yb.shape
    bn = min(block_n or 256, max(8, 1 << (n - 1).bit_length()))
    bb = min(block_b or 128, max(8, 1 << (b - 1).bit_length()))
    hp = pad_to(pad_to(h, bn, 0), bn, 1)
    yp = pad_to(pad_to(yb, bn, 0), bb, 1)
    e = hat_apply_pallas(hp, yp, block_n=bn, block_b=bb, interpret=interpret)
    e = e[:n, :b]
    return e[:, 0] if squeeze else e
