# Pallas TPU kernels for the paper's compute hot-spots (+ the substrate's
# attention). Each subpackage: <name>.py (pl.pallas_call + BlockSpec),
# ops.py (jit'd public wrapper), ref.py (pure-jnp oracle).
