"""Jit'd public wrapper for the pairdist kernel: padding, norms, dispatch."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.pairdist.pairdist import NORM_LANES, pairdist_pallas

__all__ = ["pairwise_sq_dists"]


@functools.partial(jax.jit, static_argnames=("block_c", "block_p", "interpret"))
def pairwise_sq_dists(u: jax.Array, *, block_c: Optional[int] = None,
                      block_p: Optional[int] = None,
                      interpret: Optional[bool] = None) -> jax.Array:
    """D[i, j] = ‖u_i − u_j‖² for U (C, P) via the Pallas kernel.

    Inputs of arbitrary (C, P) are zero-padded to block multiples (padded
    rows have zero norms and contribute nothing inside the real block) and
    sliced away on return. Blocks shrink to the (padded) matrix size for
    small problems — condition counts are typically tiny.
    """
    if interpret is None:
        interpret = default_interpret()
    c, p = u.shape
    bc = min(block_c or 128, max(8, 1 << (c - 1).bit_length()))
    bp = min(block_p or 512, max(8, 1 << (p - 1).bit_length()))
    up = pad_to(pad_to(u, bc, 0), bp, 1)
    norms = jnp.sum(up * up, axis=1)
    norms = jnp.broadcast_to(norms[:, None], (up.shape[0], NORM_LANES))
    d = pairdist_pallas(up, norms, block_c=bc, block_p=bp,
                        interpret=interpret)
    return d[:c, :c]
