"""Pallas TPU kernel: tiled pairwise squared Euclidean distances.

D[i, j] = ‖u_i − u_j‖² = ‖u_i‖² + ‖u_j‖² − 2·u_i·u_j — the RSA pattern-RDM
hot-spot (condition-mean RDMs, model RDMs from feature embeddings). The
cross-product term is the same MXU-friendly (bc × bp)·(bp × bc) contraction
as the ``gram`` kernel, accumulated over the feature-chunk grid axis in an
f32 VMEM scratch; the precomputed squared row norms ride along as a
lane-replicated (C, 128) input so the distance assembly happens in-kernel
on the final feature chunk (one fused pass, no (C, C) intermediate in HBM).

Grid: (C/bc, C/bc, P/bp) — contraction axis innermost so the output block
(i, j) is revisited on consecutive steps (the TPU output-revisiting
pattern; the accumulator stays in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_C = 128
DEFAULT_BLOCK_P = 512

NORM_LANES = 128  # squared norms are lane-replicated to the TPU tile width


def _pairdist_kernel(u_i_ref, u_j_ref, n_i_ref, n_j_ref, out_ref, acc_ref,
                     *, n_chunks: int):
    """One (i, j, kp) grid step: acc += U_i[kp] @ U_j[kp]ᵀ; assemble at end."""
    kp = pl.program_id(2)

    @pl.when(kp == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        u_i_ref[...], u_j_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(kp == n_chunks - 1)
    def _store():
        n_i = n_i_ref[:, 0].astype(acc_ref.dtype)              # (bc,)
        n_j = n_j_ref[:, 0].astype(acc_ref.dtype)
        d = n_i[:, None] + n_j[None, :] - 2.0 * acc_ref[...]
        out_ref[...] = jnp.maximum(d, 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_p", "interpret"))
def pairdist_pallas(u: jax.Array, norms: jax.Array, *,
                    block_c: int = DEFAULT_BLOCK_C,
                    block_p: int = DEFAULT_BLOCK_P,
                    interpret: bool = False) -> jax.Array:
    """D = pairwise sq. distances for U (C, P); norms (C, NORM_LANES) holds
    ‖u_i‖² lane-replicated. C % block_c == 0, P % block_p == 0.

    (The public wrapper in ops.py handles padding and norm preparation.)
    """
    c, p = u.shape
    assert c % block_c == 0 and p % block_p == 0, (c, p, block_c, block_p)
    assert norms.shape == (c, NORM_LANES), norms.shape
    grid = (c // block_c, c // block_c, p // block_p)
    if u.dtype in (jnp.bfloat16, jnp.float16):
        acc_dtype, out_dtype = jnp.float32, jnp.float32
    else:
        acc_dtype, out_dtype = u.dtype, u.dtype

    return pl.pallas_call(
        functools.partial(_pairdist_kernel, n_chunks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, block_p), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_c, block_p), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_c, NORM_LANES), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_c, NORM_LANES), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, block_c), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, c), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_c), acc_dtype)],
        interpret=interpret,
    )(u, u, norms, norms)
