"""Pure-jnp oracle for the pairdist kernel."""

import jax
import jax.numpy as jnp


def pairwise_sq_dists_ref(u: jax.Array) -> jax.Array:
    if u.dtype in (jnp.bfloat16, jnp.float16):
        u = u.astype(jnp.float32)
    n = jnp.sum(u * u, axis=1)
    d = n[:, None] + n[None, :] - 2.0 * (u @ u.T)
    return jnp.maximum(d, 0.0)
