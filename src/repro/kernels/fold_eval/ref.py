"""References for the fused fold_eval kernel.

Three independent oracles, in decreasing order of fidelity to the fused
kernel's data flow:

* :func:`fold_eval_ref` — pure-jnp single expression (what XLA lowers on
  CPU; also the engine's ``fused=False`` composite modulo Cholesky).
* :func:`fold_eval_two_kernel` — the *unfused pair* the fused kernel
  replaces: the ``hat_apply`` Pallas kernel materialises the full (N, B)
  Ê, then the ``foldsolve`` Pallas kernel solves the gathered fold
  blocks. Parity between this and the fused kernel is exactly the
  "eliminated intermediate changes nothing" claim.
* :func:`fold_eval_np` — host NumPy (LAPACK solves, float64 by default),
  the ground truth the property tests pin both Pallas paths against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fold_eval_ref(h_rows: jax.Array, h_te: jax.Array, y: jax.Array,
                  y_te: jax.Array):
    """Pure-jnp oracle. Returns (ė_Te, ê_Te), both (K, m, B)."""
    e = y_te - jnp.einsum("kmn,nb->kmb", h_rows, y)
    m = h_te.shape[-1]
    eye = jnp.eye(m, dtype=h_te.dtype)
    t = jax.vmap(lambda a, rhs: jnp.linalg.solve(eye - a, rhs))(h_te, e)
    return t, e


def fold_eval_two_kernel(h_rows: jax.Array, h_te: jax.Array, y: jax.Array,
                         y_te: jax.Array, *, interpret=None):
    """The unfused Pallas pair: hat_apply → (N, B) Ê in HBM → foldsolve.

    ``h_rows``/``y_te`` are per-fold gathers of an (N, N) hat matrix and
    the (N, B) batch; this reference reconstructs the pre-gather views it
    can (ê_Te = y_te − h_rows @ y) and routes the fold solve through the
    standalone ``foldsolve`` kernel — i.e. the exact two-launch data flow
    the fused kernel collapses, intermediate materialisation included.
    """
    from repro.kernels.foldsolve.ops import foldsolve

    e = y_te - jnp.einsum("kmn,nb->kmb", h_rows, y)
    t = foldsolve(h_te, e, interpret=interpret)
    return t, e


def fold_eval_np(h_rows, h_te, y, y_te):
    """Host-NumPy ground truth (LAPACK row-pivoted solves)."""
    h_rows = np.asarray(h_rows, dtype=np.float64)
    h_te = np.asarray(h_te, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    y_te = np.asarray(y_te, dtype=np.float64)
    e = y_te - np.einsum("kmn,nb->kmb", h_rows, y)
    m = h_te.shape[-1]
    t = np.stack([np.linalg.solve(np.eye(m) - h_te[k], e[k])
                  for k in range(h_te.shape[0])])
    return t, e
