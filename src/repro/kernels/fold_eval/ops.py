"""Jit'd public wrapper for the fused fold_eval kernel.

Pads the contraction (N) and batch (B) axes to block multiples — zero
padding is exact here: padded hat-row columns are zero, so padded y rows
contribute nothing to the contraction, and padded y_te columns produce
ê = 0 → ė = 0 blocks that are sliced away. Carries the same
residual-checked jitter fallback as ``foldsolve`` (see
:mod:`repro.kernels.foldsolve.ops`): the fused kernel also returns the
ê_Te block it solved against, so the residual check needs no
re-materialisation, and a failing fold re-solves only the (cheap,
standalone) fold-solve stage against the Tikhonov-shifted system — the
hat-row contraction is never repeated.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.fold_eval.fold_eval import (
    DEFAULT_BLOCK_B,
    DEFAULT_BLOCK_N,
    fold_eval_pallas,
)
from repro.kernels.foldsolve.foldsolve import foldsolve_pallas
from repro.kernels.foldsolve.ops import fold_jitter, fold_residual_bad

__all__ = ["fold_eval"]


def _block(requested: Optional[int], default: int, dim: int) -> int:
    """Shrink the block to the padded-pow2 of a small dim (same idiom as
    gram/hat_apply: avoids padding a dim far past its size)."""
    return min(requested or default, max(8, 1 << (dim - 1).bit_length()))


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_b", "interpret", "jitter")
)
def fold_eval(h_rows: jax.Array, h_te: jax.Array, y: jax.Array,
              y_te: jax.Array, *, block_n: Optional[int] = None,
              block_b: Optional[int] = None,
              interpret: Optional[bool] = None,
              jitter: Optional[str] = "auto") -> jax.Array:
    """Fused ė_Te = (I − H_Te)⁻¹ (y_Te − H·y) for all folds in one launch.

    h_rows: (K, m, N) per-fold hat rows H[te_k, :].
    h_te:   (K, m, m) diagonal fold blocks H_Te.
    y:      (N, B) label batch.   y_te: (K, m, B) gathered test labels.
    Returns ė_Te of shape (K, m, B).

    jitter: "auto" (default) enables the residual-checked retry for λ→0
        edge cases; None disables it. The retry re-solves failing folds
        with the standalone foldsolve kernel against A + ε_k I
        (ε_k = :func:`repro.kernels.foldsolve.ops.fold_jitter`), reusing
        the fused kernel's ê_Te output as the RHS.
    """
    if interpret is None:
        interpret = default_interpret()
    k, m, n = h_rows.shape
    b = y.shape[1]
    bn = _block(block_n, DEFAULT_BLOCK_N, n)
    bb = _block(block_b, DEFAULT_BLOCK_B, b)

    h_rows_p = pad_to(h_rows, bn, axis=2)
    y_p = pad_to(pad_to(y, bn, axis=0), bb, axis=1)
    y_te_p = pad_to(y_te, bb, axis=2)

    t_p, e_p = fold_eval_pallas(h_rows_p, h_te, y_p, y_te_p,
                                block_n=bn, block_b=bb, interpret=interpret)
    t, e = t_p[:, :, :b], e_p[:, :, :b]

    if jitter == "auto":
        bad = fold_residual_bad(h_te, t, e)
        eye = jnp.eye(m, dtype=h_te.dtype)
        shift = jnp.where(bad, fold_jitter(h_te), 0.0)

        def _retry(_):
            # Only the solve stage re-runs (against the already-computed
            # ê_Te); I − (H_Te − ε_k I) = A + ε_k I folds the shift into
            # h_te, so the standalone kernel is reused unmodified.
            out = foldsolve_pallas(
                h_te - shift[:, None, None] * eye[None],
                pad_to(e, bb, axis=2), interpret=interpret,
            )
            return out[:, :, :b]

        t = jax.lax.cond(jnp.any(bad), _retry, lambda _: t, None)
    elif jitter is not None:
        raise ValueError(f"jitter must be 'auto' or None, got {jitter!r}")
    return t
