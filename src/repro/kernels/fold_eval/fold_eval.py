"""Pallas TPU kernel: fused fold evaluation  ė_Te = (I − H_Te)⁻¹ (y_Te − H·y).

The Eq. 14 hot path used to be two kernel launches with an (N, B) HBM
round-trip between them: ``hat_apply`` writes the full-fit errors
Ê = Y − HY, then ``foldsolve`` gathers Ê_Te and runs the per-fold masked
Gauss-Jordan solves. This kernel fuses them in the FlashAttention style
(blocked contraction + in-VMEM epilogue, Dao et al. 2022): each fold's
grid pass streams the fold's *hat-row tiles* H[te_k, :] over the N
contraction chunks, accumulates the fold's ê block in a VMEM scratch
accumulator, and — on the last chunk — runs the fold solve in place on
that block, so the intermediate (N, B) Ê is never materialised. Only the
(K, m, B) solves ė_Te (and the matching ê_Te block, which the wrapper's
residual-checked jitter fallback needs) reach HBM.

Grid: (K, B/bb, N/bn) with the contraction axis innermost (the TPU
output-revisiting pattern — the accumulator block (k, j) stays resident
in VMEM across consecutive steps). The solve epilogue reuses the same
masked Gauss-Jordan core as the standalone ``foldsolve`` kernel
(:func:`repro.kernels.foldsolve.foldsolve.gauss_jordan_solve`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.foldsolve.foldsolve import gauss_jordan_solve

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_B = 128


def _fold_eval_kernel(h_rows_ref, h_te_ref, y_ref, y_te_ref,
                      t_ref, e_ref, acc_ref, *, m: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # the hat_apply contraction, restricted to this fold's te rows:
    # acc += H[te_k, chunk] @ Y[chunk]   →   (H·y)_Te after the last chunk
    acc_ref[...] += jnp.dot(h_rows_ref[0], y_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(c == n_chunks - 1)
    def _solve():
        e = y_te_ref[0].astype(acc_ref.dtype) - acc_ref[...]   # ê_Te block
        e_ref[0] = e.astype(e_ref.dtype)
        a = jnp.eye(m, dtype=acc_ref.dtype) - h_te_ref[0].astype(acc_ref.dtype)
        t_ref[0] = gauss_jordan_solve(a, e).astype(t_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_b", "interpret"))
def fold_eval_pallas(h_rows: jax.Array, h_te: jax.Array, y: jax.Array,
                     y_te: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
                     block_b: int = DEFAULT_BLOCK_B, interpret: bool = False):
    """Fused ė_Te = (I − H_Te)⁻¹ (y_Te − H·y) per fold; returns (ė_Te, ê_Te).

    h_rows: (K, m, N) hat rows H[te_k, :] per fold.
    h_te:   (K, m, m) diagonal fold blocks H_Te (jitter, if any, is folded
            in by the wrapper as h_te − εI, so the kernel stays shift-free).
    y:      (N, B) label batch.   y_te: (K, m, B) gathered test labels.
    N % block_n == 0 and B % block_b == 0 (the ops wrapper pads).
    """
    k, m, n = h_rows.shape
    b = y.shape[1]
    assert n % block_n == 0 and b % block_b == 0, (n, b, block_n, block_b)
    grid = (k, b // block_b, n // block_n)
    acc_dtype = jnp.float32 if y.dtype in (jnp.bfloat16, jnp.float16, jnp.float32) else y.dtype

    return pl.pallas_call(
        functools.partial(_fold_eval_kernel, m=m, n_chunks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, block_n), lambda i, j, c: (i, 0, c)),
            pl.BlockSpec((1, m, m), lambda i, j, c: (i, 0, 0)),
            pl.BlockSpec((block_n, block_b), lambda i, j, c: (c, j)),
            pl.BlockSpec((1, m, block_b), lambda i, j, c: (i, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, m, block_b), lambda i, j, c: (i, 0, j)),
            pl.BlockSpec((1, m, block_b), lambda i, j, c: (i, 0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, m, b), y.dtype),
            jax.ShapeDtypeStruct((k, m, b), y.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((m, block_b), acc_dtype)],
        interpret=interpret,
    )(h_rows, h_te, y, y_te)
