"""Pure-jnp oracle for foldsolve: batched (I − H_Te)⁻¹ ê_Te."""

import jax
import jax.numpy as jnp


def foldsolve_ref(h_te: jax.Array, e_te: jax.Array) -> jax.Array:
    m = h_te.shape[-1]
    eye = jnp.eye(m, dtype=h_te.dtype)

    def solve_one(h, e):
        return jnp.linalg.solve(eye - h, e)

    return jax.vmap(solve_one)(h_te, e_te)
