"""Jit'd public wrapper for foldsolve."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.common import default_interpret
from repro.kernels.foldsolve.foldsolve import foldsolve_pallas

__all__ = ["foldsolve"]


@functools.partial(jax.jit, static_argnames=("interpret",))
def foldsolve(h_te: jax.Array, e_te: jax.Array, *,
              interpret: Optional[bool] = None) -> jax.Array:
    """ė_Te = (I − H_Te)⁻¹ ê_Te for all folds at once.

    h_te: (K, m, m) diagonal fold blocks of the hat matrix.
    e_te: (K, m) or (K, m, B) full-fit errors (B = permutation batch).
    """
    if interpret is None:
        interpret = default_interpret()
    squeeze = e_te.ndim == 2
    e = e_te[..., None] if squeeze else e_te
    out = foldsolve_pallas(h_te, e, interpret=interpret)
    return out[..., 0] if squeeze else out
