"""Jit'd public wrapper for foldsolve, with the λ→0 jitter fallback.

The kernel's pivot-free Gauss-Jordan is exact for the SPD, well-conditioned
A = I − H_Te that ridge-regularised plans produce (λ > 0 keeps H's spectrum
inside [0, 1)). As λ → 0 in the P ≥ N regime, H_Te → I and A degenerates;
the elimination then divides by vanishing pivots and the solve degrades or
overflows. The wrapper implements the fallback the kernel docstring
promises as a *residual-checked retry*: solve once, measure the per-fold
residual ‖A ė − ê‖_∞ against √ε·(1 + ‖ê‖_∞), and — only if some fold fails
(non-finite output counts as failing) — re-solve those folds against the
Tikhonov-shifted A + ε_k I with ε_k = :func:`fold_jitter`. The retry lives
under ``lax.cond``, so the healthy steady state pays one cheap residual
contraction and never re-enters the kernel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.foldsolve.foldsolve import foldsolve_pallas

__all__ = ["foldsolve", "fold_jitter", "fold_residual_bad"]


def _residual_tol(dtype) -> float:
    """√ε acceptance threshold: far above a healthy solve's ~ε·m residual,
    far below the O(1) residual of a degenerate pivot-free elimination."""
    return float(jnp.finfo(dtype).eps) ** 0.5


def fold_jitter(h_te: jax.Array) -> jax.Array:
    """Per-fold Tikhonov shift ε_k = √ε·(1 + ‖I − H_Te[k]‖_max) — the
    jitter magnitude the retry applies (exposed so tests and callers can
    reproduce the shifted system exactly)."""
    m = h_te.shape[-1]
    eye = jnp.eye(m, dtype=h_te.dtype)
    a = eye[None] - h_te
    return _residual_tol(h_te.dtype) * (1.0 + jnp.max(jnp.abs(a), axis=(1, 2)))


def fold_residual_bad(h_te: jax.Array, t: jax.Array, e: jax.Array) -> jax.Array:
    """(K,) bool: folds whose solve t of (I − H_Te) t = e failed the
    residual check (or produced non-finite values)."""
    m = h_te.shape[-1]
    eye = jnp.eye(m, dtype=h_te.dtype)
    a = eye[None] - h_te
    r = jnp.einsum("kij,kjb->kib", a, t) - e
    scale = 1.0 + jnp.max(jnp.abs(e), axis=(1, 2))
    finite = jnp.all(jnp.isfinite(t), axis=(1, 2))
    # NaN propagates through max as NaN; comparisons with NaN are False,
    # so the finiteness term (not the residual term) must catch that case.
    resid_ok = jnp.max(jnp.abs(r), axis=(1, 2)) <= _residual_tol(e.dtype) * scale
    return ~(finite & resid_ok)


@functools.partial(jax.jit, static_argnames=("interpret", "jitter"))
def foldsolve(h_te: jax.Array, e_te: jax.Array, *,
              interpret: Optional[bool] = None,
              jitter: Optional[str] = "auto") -> jax.Array:
    """ė_Te = (I − H_Te)⁻¹ ê_Te for all folds at once.

    h_te: (K, m, m) diagonal fold blocks of the hat matrix.
    e_te: (K, m) or (K, m, B) full-fit errors (B = permutation batch).
    jitter: "auto" (default) enables the residual-checked retry against
        the shifted A + ε_k I for folds where the pivot-free elimination
        degrades (λ→0 edge cases); None disables it (raw kernel output).
    """
    if interpret is None:
        interpret = default_interpret()
    squeeze = e_te.ndim == 2
    e = e_te[..., None] if squeeze else e_te
    out = foldsolve_pallas(h_te, e, interpret=interpret)
    if jitter == "auto":
        bad = fold_residual_bad(h_te, out, e)
        m = h_te.shape[-1]
        eye = jnp.eye(m, dtype=h_te.dtype)
        shift = jnp.where(bad, fold_jitter(h_te), 0.0)

        def _retry(_):
            # I − (H_Te − ε_k I) = A + ε_k I: the shift folds into h_te,
            # so the retry reuses the unmodified kernel.
            return foldsolve_pallas(
                h_te - shift[:, None, None] * eye[None], e, interpret=interpret
            )

        out = jax.lax.cond(jnp.any(bad), _retry, lambda _: out, None)
    elif jitter is not None:
        raise ValueError(f"jitter must be 'auto' or None, got {jitter!r}")
    return out[..., 0] if squeeze else out
