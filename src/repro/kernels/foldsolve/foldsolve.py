"""Pallas TPU kernel: batched fold solve  ė_Te = (I − H_Te)⁻¹ ê_Te  (Eq. 14).

One grid step handles one fold: the (m, m) system and the (m, B) RHS batch
live entirely in VMEM (m = N/K is small by construction — the paper's whole
point is that fold solves are tiny). The solver is Gauss-Jordan elimination
on the augmented [A | E] with *full-row vector operations and masked
pivoting* rather than scalar indexing: each of the m elimination steps is a
rank-1 update of the whole (m, m+B) augmented block, which maps onto the
TPU VPU as dense elementwise/broadcast work. This is the TPU-idiomatic
replacement for the serial scalar Cholesky a CPU/GPU implementation would
use (DESIGN.md §2 hardware-adaptation).

No pivot search is performed: A = I − H_Te has eigenvalues in (0, 1] for
ridge-regularised H (H's spectrum lies in [0, 1)@λ>0 plus the intercept
direction), so it is SPD and well-conditioned without pivoting; the
wrapper (:func:`repro.kernels.foldsolve.ops.foldsolve`) implements a
residual-checked jitter fallback for λ→0 edge cases, re-solving the
Tikhonov-shifted system A + εI when the pivot-free elimination degrades.

The masked elimination core (:func:`gauss_jordan_solve`) is shared with
the fused ``fold_eval`` kernel, which runs the same solve in the epilogue
of its hat-row contraction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def gauss_jordan_solve(a: jax.Array, e: jax.Array) -> jax.Array:
    """Solve A X = E by masked Gauss-Jordan; kernel-body building block.

    a: (m, m), e: (m, B); both already in VMEM (values, not refs). Every
    elimination step is a rank-1 update of the whole augmented (m, m+B)
    block — full-row vector ops with iota masks, no scalar indexing — so
    it lowers onto the TPU VPU as dense elementwise/broadcast work.
    """
    m = a.shape[0]
    aug = jnp.concatenate([a, e.astype(a.dtype)], axis=1)    # (m, m+B)
    cols = jax.lax.broadcasted_iota(jnp.int32, aug.shape, 1)
    rows = jax.lax.broadcasted_iota(jnp.int32, aug.shape, 0)
    col_iota = jax.lax.iota(jnp.int32, aug.shape[1])
    row_iota = jax.lax.iota(jnp.int32, m)

    def step(i, aug):
        # pivot row i and pivot element a_ii, extracted with masked reduces
        row_i = jnp.sum(jnp.where(rows == i, aug, 0.0), axis=0)        # (m+B,)
        pivot = jnp.sum(jnp.where(col_iota == i, row_i, 0.0))
        row_n = row_i / pivot
        # multipliers: column i of aug, zeroed at the pivot row itself
        factors = jnp.sum(jnp.where(cols == i, aug, 0.0), axis=1)      # (m,)
        factors = jnp.where(row_iota == i, 0.0, factors)
        aug = aug - factors[:, None] * row_n[None, :]                  # rank-1
        aug = jnp.where(rows == i, row_n[None, :], aug)                # norm row
        return aug

    aug = jax.lax.fori_loop(0, m, step, aug)
    return aug[:, m:]


def _foldsolve_kernel(h_te_ref, e_ref, out_ref, *, m: int):
    a = jnp.eye(m, dtype=h_te_ref.dtype) - h_te_ref[0]       # (m, m)
    out_ref[0] = gauss_jordan_solve(a, e_ref[0]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def foldsolve_pallas(h_te: jax.Array, e_te: jax.Array, *, interpret: bool = False):
    """Solve (I − H_Te[k]) X[k] = E_Te[k] for every fold k.

    h_te: (K, m, m), e_te: (K, m, B) -> (K, m, B).
    """
    k, m, _ = h_te.shape
    b = e_te.shape[2]
    return pl.pallas_call(
        functools.partial(_foldsolve_kernel, m=m),
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, m, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m, b), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, m, b), e_te.dtype),
        interpret=interpret,
    )(h_te, e_te)
