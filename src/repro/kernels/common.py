"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling, MXU-aligned
block shapes). On this CPU-only container they are validated with
``interpret=True`` which executes the kernel bodies in Python; the
``interpret`` default below auto-detects the platform so the same call
sites run compiled on real TPUs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["default_interpret", "default_fused", "pad_to", "cdiv"]


def default_interpret() -> bool:
    """interpret=True off-TPU (CPU validation), False on real TPUs."""
    return jax.default_backend() != "tpu"


def default_fused() -> bool:
    """Resolve ``fused=None`` (auto): use the fused fold_eval kernel only
    where Pallas compiles natively. Off-TPU the kernels run in interpret
    mode (Python-speed), so auto keeps the reference XLA path — the fused
    path stays reachable everywhere by passing ``fused=True`` explicitly.
    """
    return jax.default_backend() == "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple (MXU alignment)."""
    size = x.shape[axis]
    target = cdiv(size, multiple) * multiple
    if target == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad)
