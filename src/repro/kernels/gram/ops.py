"""Jit'd public wrapper for the gram kernel: padding, centering, dispatch."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.gram.gram import gram_pallas

__all__ = ["gram", "centered_gram"]


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret", "center"))
def gram(x: jax.Array, *, center: bool = False, block_n: Optional[int] = None,
         block_p: Optional[int] = None, interpret: Optional[bool] = None) -> jax.Array:
    """G = X Xᵀ (optionally column-centered first) via the Pallas kernel.

    Inputs of arbitrary (N, P) are zero-padded to block multiples; padding
    rows are sliced away on return (zero-padding P contributes 0 to XXᵀ).
    Blocks shrink to the (padded) matrix size for small problems.
    """
    if interpret is None:
        interpret = default_interpret()
    if center:
        x = x - jnp.mean(x, axis=0, keepdims=True)
    n, p = x.shape
    bn = min(block_n or 256, max(8, 1 << (n - 1).bit_length()))
    bp = min(block_p or 512, max(8, 1 << (p - 1).bit_length()))
    xp = pad_to(pad_to(x, bn, 0), bp, 1)
    g = gram_pallas(xp, block_n=bn, block_p=bp, interpret=interpret)
    return g[:n, :n]


def centered_gram(x: jax.Array, **kw) -> jax.Array:
    """Centered Gram G_c = X_c X_cᵀ — the dual hat-matrix building block."""
    return gram(x, center=True, **kw)
