"""Jit'd public wrapper for the gram kernel: padding, centering, dispatch.

Also home of the ``precision="bf16_gram"`` mixed-precision build: the
O(N²P) Gram product — the only dimension-P contraction in the dual path —
is computed from a bf16 cast of the *centered* design with float32
accumulation (Pallas kernel and XLA fallback alike), then cast back to the
working dtype; every downstream solve stays full precision. Following the
blocked mixed-precision error analysis of Higham & Mary (2019), the
elementwise bf16 rounding of X_c bounds the Gram's relative error by
~2·2⁻⁸ ‖X_c‖² (bf16 has an 8-bit significand; the f32 accumulator
contributes O(P·2⁻²⁴), negligible), which the λ-regularised fold solves
damp rather than amplify — the documented bound the error tests pin.
Centering happens *before* the cast: means are O(‖X‖) quantities whose
bf16 rounding would otherwise leak a rank-1 error of the same order as
the signal.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.gram.gram import gram_pallas

__all__ = ["gram", "centered_gram", "centered_gram_xla", "check_precision",
           "PRECISIONS"]

#: Gram/hat build precisions: "fp32" = the working dtype end-to-end (the
#: historical behaviour; the name predates x64 test configs), "bf16_gram" =
#: bf16 inputs + f32 accumulation for the Gram product only.
PRECISIONS = ("fp32", "bf16_gram")


def check_precision(precision: Optional[str]) -> str:
    """Normalise (None → "fp32") and validate a precision name."""
    precision = precision or "fp32"
    if precision not in PRECISIONS:
        raise ValueError(f"precision must be one of {PRECISIONS}, "
                         f"got {precision!r}")
    return precision


@functools.partial(jax.jit, static_argnames=(
    "block_n", "block_p", "interpret", "center", "precision"))
def gram(x: jax.Array, *, center: bool = False, block_n: Optional[int] = None,
         block_p: Optional[int] = None, interpret: Optional[bool] = None,
         precision: Optional[str] = None) -> jax.Array:
    """G = X Xᵀ (optionally column-centered first) via the Pallas kernel.

    Inputs of arbitrary (N, P) are zero-padded to block multiples; padding
    rows are sliced away on return (zero-padding P contributes 0 to XXᵀ).
    Blocks shrink to the (padded) matrix size for small problems.
    ``precision="bf16_gram"`` casts the (centered) input to bf16 for the
    contraction — the kernel accumulates in f32 — and returns the result
    in the input dtype (see module docstring for the error bound).
    """
    if interpret is None:
        interpret = default_interpret()
    precision = check_precision(precision)
    if center:
        x = x - jnp.mean(x, axis=0, keepdims=True)
    out_dtype = x.dtype
    if precision == "bf16_gram":
        x = x.astype(jnp.bfloat16)
    n, p = x.shape
    bn = min(block_n or 256, max(8, 1 << (n - 1).bit_length()))
    bp = min(block_p or 512, max(8, 1 << (p - 1).bit_length()))
    xp = pad_to(pad_to(x, bn, 0), bp, 1)
    g = gram_pallas(xp, block_n=bn, block_p=bp, interpret=interpret)
    return g[:n, :n].astype(out_dtype)


def centered_gram(x: jax.Array, **kw) -> jax.Array:
    """Centered Gram G_c = X_c X_cᵀ — the dual hat-matrix building block."""
    return gram(x, center=True, **kw)


def centered_gram_xla(x: jax.Array, *,
                      precision: Optional[str] = None) -> jax.Array:
    """Centered Gram on the plain XLA path (no Pallas launch).

    The fallback ``fastcv.prepare`` uses when no precomputed Gram is
    supplied: at ``precision="bf16_gram"`` the centered design is cast to
    bf16 and contracted with a float32 accumulator
    (``preferred_element_type``) — the same numerics as the Pallas kernel's
    mixed-precision mode — then cast back to the input dtype.
    """
    precision = check_precision(precision)
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    if precision == "fp32":
        return xc @ xc.T
    xb = xc.astype(jnp.bfloat16)
    g = jnp.matmul(xb, xb.T, preferred_element_type=jnp.float32)
    return g.astype(x.dtype)
