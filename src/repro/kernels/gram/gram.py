"""Pallas TPU kernel: tiled Gram matrix G = X Xᵀ (syrk).

This is the dominant O(N²P) term of the analytical CV setup in the paper's
P ≫ N regime (DESIGN.md §2): the dual hat matrix needs G_c = X_c X_cᵀ once,
after which every fold/permutation is O(m²). On TPU the contraction runs on
the MXU with (bn × bp)·(bp × bn) tiles resident in VMEM, accumulating over
the feature-chunk grid axis in an f32 VMEM scratch accumulator.

Grid: (N/bn, N/bn, P/bp) — the contraction axis is the *last* (innermost)
grid dimension so the output block (i, j) is revisited on consecutive steps
(the TPU output-revisiting pattern; the accumulator stays in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_P = 512


def _gram_kernel(x_i_ref, x_j_ref, out_ref, acc_ref, *, n_chunks: int):
    """One (i, j, kp) grid step: acc += X_i[kp] @ X_j[kp]ᵀ."""
    kp = pl.program_id(2)

    @pl.when(kp == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_i_ref[...], x_j_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(kp == n_chunks - 1)
    def _store():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def gram_pallas(x: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
                block_p: int = DEFAULT_BLOCK_P, interpret: bool = False) -> jax.Array:
    """G = X @ Xᵀ for X of shape (N, P); N % block_n == 0, P % block_p == 0.

    (The public wrapper in ops.py handles padding/centering.)
    """
    n, p = x.shape
    assert n % block_n == 0 and p % block_p == 0, (n, p, block_n, block_p)
    grid = (n // block_n, n // block_n, p // block_p)
    if x.dtype in (jnp.bfloat16, jnp.float16):
        acc_dtype, out_dtype = jnp.float32, jnp.float32
    else:
        acc_dtype, out_dtype = x.dtype, x.dtype

    return pl.pallas_call(
        functools.partial(_gram_kernel, n_chunks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_p), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_p), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_n, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_n, block_n), acc_dtype)],
        interpret=interpret,
    )(x, x)
