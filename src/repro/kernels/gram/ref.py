"""Pure-jnp oracle for the gram kernel."""

import jax
import jax.numpy as jnp


def gram_ref(x: jax.Array) -> jax.Array:
    if x.dtype in (jnp.bfloat16, jnp.float16):
        return jnp.dot(x, x.T, preferred_element_type=jnp.float32)
    return x @ x.T


def centered_gram_ref(x: jax.Array) -> jax.Array:
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    return gram_ref(xc)
