"""Pallas TPU kernel: causal flash attention with GQA, local windows, softcap.

Covers the attention variants of the assigned architecture pool from one
kernel: grouped-query attention (all archs), sliding-window local attention
(gemma2 / recurrentgemma local layers), and logit soft-capping (gemma2).

Online-softmax tiling: grid (B·Hq, S/bq, S/bk) with the key axis innermost;
running max/denominator/accumulator live in VMEM scratch across the key
loop (the classic FlashAttention-2 schedule, laid out for the MXU with
(bq × d)·(d × bk) tiles). Fully-masked key blocks (beyond the causal
frontier or outside the local window) are skipped with ``pl.when`` — for
long_500k-class shapes the window skip is what makes local layers O(S·W).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, n_kblocks: int,
                  causal: bool, window: Optional[int], softcap: Optional[float]):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = jk * block_k

    # Block-level reachability: last query in block vs first key in block.
    reachable = True
    if causal:
        reachable = q_start + block_q - 1 >= k_start
    if window is not None:
        # first query in block vs last key in block: q - k < window
        reachable = jnp.logical_and(
            reachable, q_start - (k_start + block_k - 1) < window)

    @pl.when(reachable)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)                 # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_idx >= k_idx)
        if window is not None:
            mask = jnp.logical_and(mask, q_idx - k_idx < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(jk == n_kblocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           scale: float, causal: bool = True,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0; S % blocks == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    grid = (b * hq, s // block_q, s // block_k)

    def q_map(bh, iq, jk):
        return (bh // hq, bh % hq, iq, 0)

    def kv_map(bh, iq, jk):
        return (bh // hq, (bh % hq) // group, jk, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kblocks=grid[2], causal=causal, window=window, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), q_map),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
