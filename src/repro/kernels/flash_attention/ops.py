"""Jit'd public wrapper for flash attention (padding + platform dispatch)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas

__all__ = ["flash_attention"]


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, scale: float,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Self-attention (S_q == S_kv) via the Pallas kernel; pads S to block
    multiples (padded keys are causally/locally unreachable from real
    queries because they come *after* them, so results are unaffected)."""
    if interpret is None:
        interpret = default_interpret()
    s = q.shape[2]
    bq = min(block_q, max(8, 1 << (s - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (s - 1).bit_length()))
    qp = pad_to(q, bq, 2)
    kp = pad_to(k, bk, 2)
    vp = pad_to(v, bk, 2)
    if kp.shape[2] != qp.shape[2]:  # equalise padded lengths
        target = max(kp.shape[2], qp.shape[2])
        qp = pad_to(qp, target, 2)
        kp = pad_to(kp, target, 2)
        vp = pad_to(vp, target, 2)
    out = flash_attention_pallas(qp, kp, vp, scale=scale, causal=causal,
                                 window=window, softcap=softcap,
                                 block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :, :s, :]
