"""Pure-jnp oracle: dense (masked-softmax) attention with GQA/window/softcap.

Also serves as the XLA attention path used by the model substrate on
non-TPU backends and inside the multi-pod dry-run (Pallas kernels target
real TPUs; GSPMD lowers this einsum form on any backend).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, scale: float,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jax.Array:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S_kv, D). Returns (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hkv, s_kv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, s, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    q_idx = jnp.arange(s)[:, None] + (s_kv - s)   # align ends (decode offset)
    k_idx = jnp.arange(s_kv)[None, :]
    mask = jnp.ones((s, s_kv), dtype=bool)
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= (q_idx - k_idx) < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)
