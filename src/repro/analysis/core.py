"""reprolint core: file walker, rule registry, suppressions, vocab loading.

Everything here is stdlib-only on purpose: the checker AST-parses the
serving stack (including the vocabularies it enforces — ``STAGES`` from
``serve/trace.py``, ``METRICS`` from ``serve/obs.py``) instead of
importing it, so the ``reprolint`` CI job needs no jax install and the
checker can never be broken by the code it is checking.
"""

from __future__ import annotations

import ast
import dataclasses
import functools
import json
import re
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

#: Rule id reported for a suppression comment that carries no reason.
BAD_SUPPRESSION = "RL000"

# `# reprolint: ignore[RL001]` or `# reprolint: ignore[RL001,RL004] -- reason`
_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*ignore\[([A-Za-z0-9_\s,]+)\]\s*(?:--\s*(\S.*))?$"
)

#: In-file scope pragmas. A pragma on its own comment line marks the
#: innermost enclosing function (or the whole module when at top level).
PRAGMAS = ("host-path", "monotonic-time", "host-float64")
_PRAGMA_RE = re.compile(r"^\s*#\s*reprolint:\s*(host-path|monotonic-time|host-float64)\s*$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One reported violation, pointing at a file:line."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


class Rule:
    """Base class for a reprolint rule: ``check(ctx)`` yields findings."""

    id = "RL???"
    title = ""

    def check(self, ctx: "FileContext") -> Iterator[Finding]:  # pragma: no cover
        raise NotImplementedError


@dataclasses.dataclass
class FileContext:
    """Parsed view of one file handed to every rule."""

    path: Path
    source: str
    tree: ast.Module
    lines: List[str]
    # line -> rule ids suppressed there (only suppressions WITH a reason)
    suppressions: Dict[int, set]
    # lines carrying an ignore[...] with no justification (RL000)
    bare_suppression_lines: List[int]
    # pragma directive -> list of line numbers where it appears
    pragma_lines: Dict[str, List[int]]
    # (start, end) line intervals of every function, innermost-last
    _func_spans: List[Tuple[int, int]] = dataclasses.field(default_factory=list)

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule, str(self.path), node.lineno, node.col_offset, message)

    # -- pragma scoping ----------------------------------------------------

    def _spans(self) -> List[Tuple[int, int]]:
        if not self._func_spans:
            for node in ast.walk(self.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._func_spans.append((node.lineno, node.end_lineno or node.lineno))
        return self._func_spans

    def pragma_regions(self, directive: str) -> List[Tuple[int, int]]:
        """Line intervals governed by ``directive`` pragmas in this file.

        A pragma inside a function marks that function's full span
        (including nested functions); a top-level pragma marks the whole
        module. Returns [] when the file never opts in.
        """
        regions: List[Tuple[int, int]] = []
        for line in self.pragma_lines.get(directive, ()):
            inner: Optional[Tuple[int, int]] = None
            for start, end in self._spans():
                if start <= line <= end:
                    if inner is None or (start >= inner[0] and end <= inner[1]):
                        inner = (start, end)
            regions.append(inner if inner is not None else (1, len(self.lines)))
        return regions

    def in_region(self, directive: str, line: int) -> bool:
        return any(start <= line <= end for start, end in self.pragma_regions(directive))


def _scan_comments(lines: Sequence[str]):
    """Extract suppressions (with/without reason) and pragma lines."""
    suppressions: Dict[int, set] = {}
    bare: List[int] = []
    pragmas: Dict[str, List[int]] = {}
    for i, text in enumerate(lines, start=1):
        if "reprolint" not in text:
            continue
        m = _PRAGMA_RE.match(text)
        if m:
            pragmas.setdefault(m.group(1), []).append(i)
            continue
        m = _SUPPRESS_RE.search(text)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            if m.group(2):
                suppressions.setdefault(i, set()).update(rules)
            else:
                bare.append(i)
    return suppressions, bare, pragmas


def parse_file(path: Path) -> Optional[FileContext]:
    source = path.read_text(encoding="utf-8")
    tree = ast.parse(source, filename=str(path))
    lines = source.splitlines()
    suppressions, bare, pragmas = _scan_comments(lines)
    return FileContext(path, source, tree, lines, suppressions, bare, pragmas)


# ---------------------------------------------------------------------------
# Vocabulary extraction (AST, not import — keeps the checker jax-free)
# ---------------------------------------------------------------------------


def _serve_dir() -> Path:
    return Path(__file__).resolve().parent.parent / "serve"


def _module_constant(path: Path, name: str):
    """literal_eval the module-level ``name = <literal>`` assignment."""
    tree = ast.parse(path.read_text(encoding="utf-8"), filename=str(path))
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        if any(isinstance(t, ast.Name) and t.id == name for t in targets):
            return ast.literal_eval(node.value)
    raise LookupError(f"{name} not found as a literal assignment in {path}")


@functools.lru_cache(maxsize=None)
def load_stages() -> Tuple[str, ...]:
    """The fixed trace-stage vocabulary (``serve/trace.py:STAGES``)."""
    return tuple(_module_constant(_serve_dir() / "trace.py", "STAGES"))


@functools.lru_cache(maxsize=None)
def load_metrics() -> dict:
    """The central metric declarations (``serve/obs.py:METRICS``)."""
    return dict(_module_constant(_serve_dir() / "obs.py", "METRICS"))


# ---------------------------------------------------------------------------
# Registry + driver
# ---------------------------------------------------------------------------


def all_rules() -> List[Rule]:
    # Imported lazily to avoid a cycle (rule modules import this module).
    from repro.analysis import rules_dtype, rules_host, rules_locks, rules_vocab

    rules: List[Rule] = []
    for mod in (rules_host, rules_vocab, rules_locks, rules_dtype):
        rules.extend(mod.RULES)
    return rules


def iter_py_files(paths: Iterable[str]) -> Iterator[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            yield from sorted(x for x in p.rglob("*.py") if "__pycache__" not in x.parts)
        elif p.suffix == ".py":
            yield p


def check_file(path: Path, rules: Sequence[Rule]) -> List[Finding]:
    try:
        ctx = parse_file(path)
    except SyntaxError as e:
        return [Finding(BAD_SUPPRESSION, str(path), e.lineno or 1, 0, f"syntax error: {e.msg}")]
    findings: List[Finding] = []
    for rule in rules:
        for f in rule.check(ctx):
            # A suppression only counts with a written justification; a
            # bare ignore[...] suppresses nothing and is reported below.
            if rule.id in ctx.suppressions.get(f.line, ()):
                continue
            findings.append(f)
    for line in ctx.bare_suppression_lines:
        findings.append(
            Finding(
                BAD_SUPPRESSION,
                str(path),
                line,
                0,
                "suppression without a justification "
                "(write `# reprolint: ignore[RULE] -- <reason>`)",
            )
        )
    return findings


def run(paths: Iterable[str], rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Check every ``.py`` under ``paths``; return findings sorted by site."""
    rules = list(rules) if rules is not None else all_rules()
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        findings.extend(check_file(path, rules))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def render_human(findings: Sequence[Finding]) -> str:
    lines = [f.render() for f in findings]
    n = len(findings)
    lines.append(f"reprolint: {n} finding{'s' if n != 1 else ''}")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding]) -> str:
    return json.dumps(
        {"findings": [f.to_dict() for f in findings], "count": len(findings)}, indent=2
    )
