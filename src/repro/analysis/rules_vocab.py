"""RL002 trace-stage vocabulary + RL003 metrics discipline.

Both rules check string-literal call sites against vocabularies that are
AST-extracted from their single source of truth (never duplicated in the
checker): ``STAGES`` in ``serve/trace.py`` and ``METRICS`` in
``serve/obs.py``. A typo'd stage or metric name therefore cannot drift
silently — it either matches the declaration or fails lint.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.core import FileContext, Finding, Rule, load_metrics, load_stages


def _literal_str(node: Optional[ast.AST]) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


class StageVocabulary(Rule):
    """Every stage literal handed to trace APIs must be a STAGES member."""

    id = "RL002"
    title = "trace-stage vocabulary: span/stage literals must come from STAGES"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        stages = set(load_stages())
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = None
            f = node.func
            if isinstance(f, ast.Attribute):
                if f.attr == "span" and node.args:
                    name = _literal_str(node.args[0])
                elif f.attr == "add" and len(node.args) == 2:
                    # Trace.add(stage, seconds) — two positional args keeps
                    # set.add()/argparse-style .add() out of scope.
                    name = _literal_str(node.args[0])
            if name is None:
                for kw in node.keywords:
                    if kw.arg == "stage":
                        name = _literal_str(kw.value)
            if name is not None and name not in stages:
                yield ctx.finding(
                    self.id,
                    node,
                    f"stage {name!r} is not in the STAGES vocabulary "
                    f"(repro.serve.trace.STAGES: {', '.join(sorted(stages))})",
                )


_USE_KINDS = {"inc": "counter", "observe": "histogram"}
_REG_KINDS = {"counter": "counter", "gauge": "gauge", "histogram": "histogram"}

# Label-value expressions considered unbounded (cardinality bombs): any
# string formatting/construction at the call site. Names/attributes are
# assumed bounded — the runtime _other fold still backstops them.
def _is_unbounded_value(node: ast.AST) -> bool:
    if isinstance(node, ast.JoinedStr):
        return True
    if isinstance(node, ast.BinOp):
        return True  # "x-" + y, "x%s" % y, and friends
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name) and f.id in {"str", "repr", "hex", "format"}:
            return True
        if isinstance(f, ast.Attribute) and f.attr in {"format", "join"}:
            return True
    return False


class MetricsDiscipline(Rule):
    """Metric names, label keys and label-value boundedness vs METRICS."""

    id = "RL003"
    title = "metrics discipline: call sites must match the central METRICS table"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        metrics = load_metrics()
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) or not isinstance(node.func, ast.Attribute):
                continue
            attr = node.func.attr
            if attr in _USE_KINDS:
                yield from self._check_use(ctx, node, attr, metrics)
            elif attr in _REG_KINDS:
                yield from self._check_registration(ctx, node, attr, metrics)

    def _check_use(self, ctx, node: ast.Call, attr: str, metrics: dict):
        name = _literal_str(node.args[0]) if node.args else None
        if name is None:
            return
        spec = metrics.get(name)
        if spec is None:
            yield ctx.finding(
                self.id,
                node,
                f"metric {name!r} has no declaration in repro.serve.obs.METRICS",
            )
            return
        want_kind = _USE_KINDS[attr]
        if spec["kind"] != want_kind:
            yield ctx.finding(
                self.id,
                node,
                f".{attr}() needs a {want_kind} but {name!r} is declared "
                f"as a {spec['kind']}",
            )
        if any(kw.arg is None for kw in node.keywords):
            return  # **labels splat: keys unknowable statically
        keys = {kw.arg for kw in node.keywords}
        declared = set(spec.get("labels", ()))
        if keys != declared:
            yield ctx.finding(
                self.id,
                node,
                f"label keys {sorted(keys)} do not match the declared "
                f"label set {sorted(declared)} for {name!r}",
            )
        for kw in node.keywords:
            if kw.arg in declared and _is_unbounded_value(kw.value):
                yield ctx.finding(
                    self.id,
                    node,
                    f"label {kw.arg!r} value is built by string formatting "
                    "(unbounded cardinality); pass a value from a closed vocabulary",
                )

    def _check_registration(self, ctx, node: ast.Call, attr: str, metrics: dict):
        name = _literal_str(node.args[0]) if node.args else None
        if name is None:
            return
        spec = metrics.get(name)
        if spec is None:
            yield ctx.finding(
                self.id,
                node,
                f"metric {name!r} is registered but not declared in "
                "repro.serve.obs.METRICS",
            )
            return
        if spec["kind"] != _REG_KINDS[attr]:
            yield ctx.finding(
                self.id,
                node,
                f"{name!r} is declared as a {spec['kind']} but registered "
                f"via .{attr}()",
            )
        for kw in node.keywords:
            if kw.arg == "labels":
                try:
                    got = tuple(ast.literal_eval(kw.value))
                except (ValueError, SyntaxError):
                    return
                if got != tuple(spec.get("labels", ())):
                    yield ctx.finding(
                        self.id,
                        node,
                        f"label keys {list(got)} do not match the declared "
                        f"label set {list(spec.get('labels', ()))} for {name!r}",
                    )


RULES = [StageVocabulary(), MetricsDiscipline()]
