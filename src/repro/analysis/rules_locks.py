"""RL004 lock discipline: ``_GUARDED_BY`` attrs only mutate under lock.

A lightweight *lexical* race detector — the check that must exist before
the ROADMAP's replica-fleet work multiplies the thread-safety surface.
Classes declare their concurrency contract as data::

    class PlanCache:
        _GUARDED_BY = {"_entries": "_lock", "stats": "_lock"}
        _LOCKED_HELPERS = ("_evict_over_budget",)  # callers hold _lock

Any mutation of ``self.<attr>`` (assignment, augmented assignment, item
assignment/deletion, or a mutating method call like ``.append``/``.pop``)
for an attr in ``_GUARDED_BY`` must sit lexically inside
``with self.<lock>``. ``__init__`` is exempt (no concurrent access before
construction completes), as are helpers named in ``_LOCKED_HELPERS`` —
the declared way to say "my callers hold the lock". Nested functions and
lambdas reset the held-lock state: they run later, possibly lock-free.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set, Tuple

from repro.analysis.core import FileContext, Finding, Rule

#: Method names that mutate their receiver in-place.
MUTATORS = frozenset(
    {
        "append",
        "appendleft",
        "extend",
        "insert",
        "pop",
        "popitem",
        "popleft",
        "remove",
        "clear",
        "add",
        "discard",
        "update",
        "setdefault",
        "move_to_end",
        "sort",
        "reverse",
    }
)


def _self_attr_root(node: ast.AST) -> Optional[str]:
    """The ``<attr>`` in an expression rooted at ``self.<attr>``, else None.

    Walks down chains like ``self.stats.bytes_in_use`` or
    ``self._entries[key]`` to their base attribute on ``self``.
    """
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        base = node.value
        if (
            isinstance(node, ast.Attribute)
            and isinstance(base, ast.Name)
            and base.id == "self"
        ):
            return node.attr
        node = base
    return None


def _class_literal(cls: ast.ClassDef, name: str):
    for stmt in cls.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target]
        if any(isinstance(t, ast.Name) and t.id == name for t in targets):
            try:
                return ast.literal_eval(stmt.value)
            except (ValueError, SyntaxError):
                return None
    return None


class LockDiscipline(Rule):
    id = "RL004"
    title = "lock discipline: _GUARDED_BY attrs may only mutate under their lock"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                guarded = _class_literal(node, "_GUARDED_BY")
                if not isinstance(guarded, dict) or not guarded:
                    continue
                helpers = set(_class_literal(node, "_LOCKED_HELPERS") or ())
                yield from self._check_class(ctx, node, guarded, helpers)

    def _check_class(
        self, ctx, cls: ast.ClassDef, guarded: Dict[str, str], helpers: Set[str]
    ):
        for stmt in cls.body:
            if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if stmt.name == "__init__" or stmt.name in helpers:
                continue
            for body_stmt in stmt.body:
                yield from self._walk(ctx, body_stmt, guarded, frozenset())

    # -- recursive walk tracking the set of held lock attrs ----------------

    def _walk(self, ctx, node: ast.AST, guarded, held: frozenset):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            # Closures execute later, possibly without the lock.
            body = node.body if isinstance(node.body, list) else [node.body]
            for child in body:
                yield from self._walk(ctx, child, guarded, frozenset())
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            newly = {
                item.context_expr.attr
                for item in node.items
                if isinstance(item.context_expr, ast.Attribute)
                and isinstance(item.context_expr.value, ast.Name)
                and item.context_expr.value.id == "self"
            }
            for child in node.body:
                yield from self._walk(ctx, child, guarded, held | newly)
            return

        yield from self._check_node(ctx, node, guarded, held)
        for child in ast.iter_child_nodes(node):
            yield from self._walk(ctx, child, guarded, held)

    def _check_node(self, ctx, node: ast.AST, guarded, held: frozenset):
        sites = []  # (attr, verb)
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for t in targets:
                for el in self._flatten_target(t):
                    attr = _self_attr_root(el)
                    if attr in guarded:
                        sites.append((attr, "assigned"))
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                attr = _self_attr_root(t)
                if attr in guarded:
                    sites.append((attr, "deleted"))
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in MUTATORS:
                attr = _self_attr_root(node.func.value)
                if attr in guarded:
                    sites.append((attr, f"mutated via .{node.func.attr}()"))
        for attr, verb in sites:
            lock = guarded[attr]
            if lock not in held:
                yield ctx.finding(
                    self.id,
                    node,
                    f"self.{attr} is {verb} outside `with self.{lock}` "
                    f"(declared in _GUARDED_BY)",
                )

    @staticmethod
    def _flatten_target(t: ast.AST):
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                yield from LockDiscipline._flatten_target(el)
        else:
            yield t


RULES = [LockDiscipline()]
