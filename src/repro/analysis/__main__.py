"""CLI: ``python -m repro.analysis [paths...] [--json] [--rules RL001,..]``.

Exit status is 0 when no findings, 1 when any rule fired — suitable for
CI gating in both directions (clean tree passes, seeded fixtures fail).
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis.core import all_rules, render_human, render_json, run


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: AST-based invariant checker for the serving stack",
    )
    parser.add_argument(
        "paths",
        nargs="*",
        default=["src", "benchmarks"],
        help="files or directories to check (default: src benchmarks)",
    )
    parser.add_argument("--json", action="store_true", help="machine-readable output")
    parser.add_argument(
        "--rules",
        default="",
        help="comma-separated rule ids to run (default: all)",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule table and exit"
    )
    args = parser.parse_args(argv)

    rules = all_rules()
    if args.list_rules:
        for rule in rules:
            print(f"{rule.id}  {rule.title}")
        return 0
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = wanted - {r.id for r in rules}
        if unknown:
            parser.error(f"unknown rules: {sorted(unknown)}")
        rules = [r for r in rules if r.id in wanted]

    findings = run(args.paths, rules=rules)
    print(render_json(findings) if args.json else render_human(findings))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
