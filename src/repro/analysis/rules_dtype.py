"""RL005 host-float64 policy: no sub-float64 dtypes in declared regions.

PR 8's incremental plan math (``fastcv.update_plan`` / ``downdate_plan``
/ ``sliding_window``, per arXiv 2401.13185) is bit-exact against a
from-scratch rebuild *only because* every host-side correction stays in
IEEE float64. A single float32 cast in that lineage silently degrades
the Woodbury corrections below test tolerances. Files opt in with a
``# reprolint: host-float64`` pragma (module- or function-scoped); any
sub-64-bit float/complex dtype token inside the region is flagged —
whether spelled ``np.float32``, ``dtype="float32"`` or
``.astype(jnp.bfloat16)``.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import FileContext, Finding, Rule

SUB_F64_DTYPES = frozenset(
    {
        "float32",
        "float16",
        "bfloat16",
        "half",
        "single",
        "complex64",
    }
)

_NUMERIC_ROOTS = {"np", "numpy", "jnp"}


class HostFloat64(Rule):
    id = "RL005"
    title = "host-float64 policy: no sub-float64 dtypes in declared regions"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        regions = ctx.pragma_regions("host-float64")
        if not regions:
            return
        for node in ast.walk(ctx.tree):
            token = None
            if (
                isinstance(node, ast.Attribute)
                and node.attr in SUB_F64_DTYPES
                and isinstance(node.value, ast.Name)
                and node.value.id in _NUMERIC_ROOTS
            ):
                token = f"{node.value.id}.{node.attr}"
            elif (
                isinstance(node, ast.Constant)
                and isinstance(node.value, str)
                and node.value in SUB_F64_DTYPES
            ):
                token = repr(node.value)
            if token is None or not any(s <= node.lineno <= e for s, e in regions):
                continue
            yield ctx.finding(
                self.id,
                node,
                f"sub-float64 dtype {token} in a host-float64 region — the "
                "Woodbury update lineage is only exact in float64 "
                "(arXiv 2401.13185)",
            )


RULES = [HostFloat64()]
