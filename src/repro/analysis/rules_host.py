"""RL001 jit-hygiene: host paths stay off eager jnp; timing stays monotonic.

Two sub-checks, both scoped by in-file pragmas:

* In ``# reprolint: host-path`` regions (the MicroBatcher coalescing
  path, the update-group assembly in workload.py/engine.py), any eager
  ``jnp`` array *construction or assembly* op is flagged — each call
  compiles a fresh tiny XLA executable per novel shape signature, the
  exact recompile-churn class PR 3 debugged by hand. ``jnp.asarray`` is
  explicitly allowed: it is the sanctioned device-transfer entry point
  (a ``device_put``, not a compilation).
* In ``# reprolint: monotonic-time`` regions (batching, tracing, server
  gather loops), ``time.time()`` is flagged — wall clocks jump under
  NTP slew and broke batch deadlines in PR 6; use
  ``time.monotonic()``/``time.perf_counter()``.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import FileContext, Finding, Rule

# Eager assembly/construction ops that trigger a per-shape XLA compile.
# (asarray is deliberately absent: device_put does not compile.)
JNP_CHURN_OPS = frozenset(
    {
        "concatenate",
        "pad",
        "stack",
        "hstack",
        "vstack",
        "dstack",
        "column_stack",
        "row_stack",
        "tile",
        "repeat",
        "split",
        "array_split",
        "append",
        "insert",
        "delete",
        "roll",
        "resize",
        "broadcast_to",
        "array",
        "zeros",
        "ones",
        "full",
        "empty",
        "arange",
        "linspace",
        "eye",
    }
)

_JNP_ROOTS = {"jnp"}


def _is_jnp(node: ast.AST) -> bool:
    """True for ``jnp`` or ``jax.numpy`` expression roots."""
    if isinstance(node, ast.Name):
        return node.id in _JNP_ROOTS
    if isinstance(node, ast.Attribute):
        return (
            node.attr == "numpy"
            and isinstance(node.value, ast.Name)
            and node.value.id == "jax"
        )
    return False


def _is_time_time(call: ast.Call) -> bool:
    f = call.func
    return (
        isinstance(f, ast.Attribute)
        and f.attr == "time"
        and isinstance(f.value, ast.Name)
        and f.value.id == "time"
    )


class JitHygiene(Rule):
    id = "RL001"
    title = "jit-hygiene: no eager jnp assembly / time.time() on declared host paths"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        host = ctx.pragma_regions("host-path")
        mono = ctx.pragma_regions("monotonic-time")
        if not host and not mono:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            line = node.lineno
            f = node.func
            if (
                host
                and isinstance(f, ast.Attribute)
                and f.attr in JNP_CHURN_OPS
                and _is_jnp(f.value)
                and any(s <= line <= e for s, e in host)
            ):
                yield ctx.finding(
                    self.id,
                    node,
                    f"eager jnp.{f.attr} on a declared host path compiles per novel "
                    "shape; assemble in host numpy and enter the device once via "
                    "jnp.asarray",
                )
            if mono and _is_time_time(node) and any(s <= line <= e for s, e in mono):
                yield ctx.finding(
                    self.id,
                    node,
                    "time.time() in monotonic-time code (wall clocks jump); use "
                    "time.monotonic() or time.perf_counter()",
                )


RULES = [JitHygiene()]
