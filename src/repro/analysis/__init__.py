"""repro.analysis — ``reprolint``, an AST-based invariant checker.

The serving stack rests on invariants that used to live only in review
comments: host coalescing paths must not assemble arrays with eager
``jnp`` ops (PR 3's recompile-churn class), batching/trace code must use
monotonic clocks (PR 6's bug class), span names must come from the fixed
:data:`repro.serve.trace.STAGES` vocabulary, metric call sites must match
the central :data:`repro.serve.obs.METRICS` declarations, lock-guarded
attributes (``_GUARDED_BY``) may only be mutated under their lock, and
the fastcv update lineage must stay float64 (arXiv 2401.13185 exactness).

``reprolint`` turns each of those into a mechanical check over the AST —
no imports of the checked code, no jax dependency — so CI catches the
bug class at lint time instead of a bench-gate bisection later.

Usage::

    python -m repro.analysis src benchmarks          # human output
    python -m repro.analysis --json src benchmarks   # machine output

Rules
-----
==========  ===========================================================
RL001       jit-hygiene: no eager ``jnp`` assembly / ``time.time()`` in
            declared host-path / monotonic-time regions
RL002       trace-stage vocabulary: span/stage literals must be STAGES
RL003       metrics discipline: names + label keys must match METRICS;
            label values must come from bounded sources
RL004       lock discipline: ``_GUARDED_BY`` attrs mutate under lock
RL005       host-float64 policy: no sub-float64 dtypes in declared
            host-float64 regions (fastcv update lineage)
RL000       a ``reprolint: ignore`` suppression without a justification
==========  ===========================================================

Suppression syntax (the justification is *mandatory*)::

    x = jnp.concatenate(parts)  # reprolint: ignore[RL001] -- shapes repeat, jit-cache hit

Scope declarations are in-file pragmas, so a module (or fixture) opts
itself in and the checker needs no path configuration::

    # reprolint: host-path        (module- or function-scoped)
    # reprolint: monotonic-time
    # reprolint: host-float64
"""

from repro.analysis.core import (  # noqa: F401
    BAD_SUPPRESSION,
    Finding,
    all_rules,
    load_metrics,
    load_stages,
    render_human,
    render_json,
    run,
)
